"""The dispatch engine: every GEMM in the model layer lands here.

``dispatch(op, a, b)`` computes one of the three training GEMMs —
``"NT"`` (``a @ b^T``), ``"NN"`` (``a @ b``) or ``"TN"`` (``a^T @ b``) —
through whichever *(candidate, tile config)* the scoped policy picks for
the ``OpKey`` (``policy.current_policy()``); model code never threads a
selector argument.  Because JAX shapes are static under ``jit``, the
policy runs once per distinct key at trace time and contributes nothing
to the compiled step.

``dispatch_batched(op, a, b)`` is the batched entry point for the
attention contractions — ``"BNT"`` (``Q @ K^T`` logits) and ``"BNN"``
(``probs @ V``): the leading batch/head axes of both operands collapse to
one batch extent ``g`` and the policy selects over the batched candidate
sets, so one ``use_policy(...)`` scope governs dense *and* attention
GEMMs in train and serve.

``dispatch_attention(q, k, v, ...)`` raises the decision from one op to
a *plan* over the whole attention subgraph: the policy answers the
paired ``ATTN`` OpKey with either the fused flash kernel at a learned
``(bq, bk)`` tile (``kernels/attention_fused.py`` — the logits matrix
never touches HBM) or the existing unfused plan, whose ``BNT`` and
``BNN`` sub-GEMMs are then dispatched under their own per-op keys.  The
fallback chain terminates at the unfused plan, so a faulted or
quarantined fused kernel degrades to exactly the pair of batched
dispatches the model ran before fusion existed.

Both entry points are ``custom_vjp``-wrapped: the backward rules rebuild
gradient OpKeys and re-enter dispatch — the 2-D op space {NT, NN, TN} is
closed under differentiation, and the batched space {BNT, BNN} is closed
modulo one explicit operand transpose — so the scope must wrap the whole
``value_and_grad`` call (forward *and* backward trace), not just the
forward pass.

``dispatch_report()`` renders the per-(op, candidate, config) decision
counts of the scoped policy — surfaced at the end of train/serve runs so
dispatch stays observable in production.

The pre-op-space compatibility layer (``dispatch_nt``, positional
``select(m, n, k, dsize)`` adaptation, bare-string decisions) was removed
after its one-release deprecation cycle; those call patterns now raise
clean ``TypeError``/``AttributeError``s pointing at the op-space API.
"""

from __future__ import annotations

import functools
import warnings
from typing import Optional

import jax
import numpy as np

from . import faults
from .candidates import DEFAULT_BY_OP, fallback_chain, get_candidate
from .opkey import BATCHED_OPS, OPS, OpKey, check_op
from .policy import (
    AnalyticPolicy,
    AutotunePolicy,
    CascadePolicy,
    Decision,
    FixedPolicy,
    ModelPolicy,
    SelectionPolicy,
    current_policy,
    default_policy,
    use_policy,
)

__all__ = [
    "dispatch",
    "dispatch_attention",
    "dispatch_batched",
    "dispatch_report",
    "health_report",
    "run_decision",
    "DispatchError",
    "policy_select",
    "policy_from_spec",
    "add_policy_argument",
    "use_policy",
    "current_policy",
    "default_policy",
]


class DispatchError(RuntimeError):
    """Every arm of an OpKey's fallback chain failed — raised only when
    even the op's XLA reference cannot run (the chain's terminal arm is
    always attempted, quarantined or not)."""

POLICY_SPEC_HELP = (
    "dispatch policy: model[:artifact.json] | fixed:<NAME>[@BMxBNxBK] | "
    "fixed:nt=<NAME>[@cfg],nn=...,tn=...,bnt=...,bnn=...,"
    "attn=<fused|unfused>[@BQxBK] | analytic | "
    "cascade:<A,B,...> | autotune[:cache.json]"
)

# ``fixed:attn=...`` accepts the plan-member aliases alongside literal
# candidate names; the fused arm's tile configs are (bq, bk) pairs.
_ATTN_ALIASES = {"fused": "FUSED_ATTN", "unfused": "UNFUSED_ATTN"}

_WARNED: set = set()


def _warn_once(tag: str, msg: str) -> None:
    if tag not in _WARNED:
        _WARNED.add(tag)
        warnings.warn(msg, UserWarning, stacklevel=3)


def _spec_error(msg: str) -> ValueError:
    """Every malformed spec gets the same actionable hint."""
    return ValueError(f"{msg} ({POLICY_SPEC_HELP})")


def policy_select(policy: SelectionPolicy, key: OpKey) -> Decision:
    """Run ``policy.select`` on an ``OpKey`` and validate the decision.

    Policies must return a ``Decision(name, config)`` — a bare candidate
    name (the pre-op-space convention, removed after its deprecation
    release) raises a clean ``TypeError``.  A decision naming a candidate
    that does not implement ``key.op`` (a mis-op'd policy) degrades to the
    op's reference rather than executing a kernel on operands in the wrong
    storage layout (warns once per process — that is a policy bug, not a
    deprecation).
    """
    decision = policy.select(key)
    if isinstance(decision, str):
        raise TypeError(
            f"policy {policy!r} returned the bare candidate name "
            f"{decision!r}; policies must return a Decision(name, config) "
            "— the bare-string adapter was removed with the op-space "
            "deprecation cycle"
        )
    if key.op not in get_candidate(decision.name).ops:
        _warn_once(
            "op-mismatched-decision",
            f"policy {policy!r} returned candidate {decision.name!r} for an "
            f"op it does not implement; dispatching the op's reference "
            "instead",
        )
        decision = Decision(DEFAULT_BY_OP[key.op], None)
    return decision


def _decision_chain(op: str, decision: Decision) -> list:
    """The decisions dispatch will attempt, in order: the selected arm;
    the same candidate degraded to its default tiling (an explicit tile
    is the most fragile part of a decision — shed it before shedding the
    algorithm); then the registry's per-op fallback chain, terminating at
    the op's XLA reference."""
    chain = [decision]
    if decision.config is not None:
        chain.append(Decision(decision.name, None))
    for name in fallback_chain(op, decision.name):
        if name != decision.name:
            chain.append(Decision(name, None))
    return chain


def run_decision(key: OpKey, decision: Decision, a, b):
    """Execute a policy decision fault-tolerantly.

    Walks the decision's fallback chain: a candidate that raises is
    recorded in the quarantine ledger (``core/faults.py`` — every policy's
    admissible set excludes it from then on) and the next arm runs.
    Quarantined non-terminal arms are skipped without attempting them; the
    terminal arm — the op's always-runnable XLA reference — is attempted
    even when quarantined, because there is nothing beneath it.  Raises
    ``DispatchError`` only when the whole chain failed."""
    chain = _decision_chain(key.op, decision)
    last_err: Optional[BaseException] = None
    for i, dec in enumerate(chain):
        terminal = i == len(chain) - 1
        if not terminal and faults.is_quarantined(dec.name, key.op, dec.config):
            continue
        try:
            faults.check_candidate_fault(dec.name, key.op)
            out = get_candidate(dec.name).run(a, b, dec.config)
        except (KeyboardInterrupt, SystemExit):
            raise
        except Exception as e:
            faults.quarantine(dec.name, key.op, dec.config, e)
            _warn_once(
                f"quarantined:{dec.label()}:{key.op}",
                f"candidate {dec.label()!r} failed on op {key.op!r} "
                f"({type(e).__name__}: {e}); quarantined for this process, "
                "dispatch degrades down the fallback chain",
            )
            last_err = e
            continue
        if (dec.name, dec.config) != (decision.name, decision.config):
            faults.record_fallback(key.op, decision.label(), dec.label())
        return out
    raise DispatchError(
        f"every arm of the fallback chain for {key} failed: "
        f"{[d.label() for d in chain]}"
    ) from last_err


def _run(op: str, a, b):
    """Select and execute one 2-D GEMM (the custom_vjp core)."""
    import jax.numpy as jnp

    if op == "NT":  # a:(m,k) b:(n,k)
        m, k = a.shape
        n = b.shape[0]
    elif op == "NN":  # a:(m,k) b:(k,n)
        m, k = a.shape
        n = b.shape[1]
    else:  # TN: a:(k,m) b:(k,n)
        k, m = a.shape
        n = b.shape[1]
    key = OpKey(op, int(m), int(n), int(k), int(jnp.dtype(a.dtype).itemsize))
    decision = policy_select(current_policy(), key)
    return run_decision(key, decision, a, b)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _dispatch2(op: str, a, b):
    return _run(op, a, b)


def _dispatch2_fwd(op: str, a, b):
    return _run(op, a, b), (a, b)


def _dispatch2_bwd(op: str, res, g):
    """Backward rule: each gradient GEMM is itself a dispatch — the op
    space {NT, NN, TN} is closed under differentiation, so both gradients
    of every op land back on a policy-governed op.  (First-order reverse
    mode only: custom_vjp does not support forward-mode/higher-order.)"""
    a, b = res
    if op == "NT":  # C = A B^T: dA = G @ B (NN), dB = G^T @ A (TN)
        da = _dispatch2("NN", g, b)
        db = _dispatch2("TN", g, a)
    elif op == "NN":  # C = A B: dA = G @ B^T (NT), dB = A^T @ G (TN)
        da = _dispatch2("NT", g, b)
        db = _dispatch2("TN", a, g)
    else:  # TN, C = A^T B: dA = B @ G^T (NT), dB = A @ G (NN)
        da = _dispatch2("NT", b, g)
        db = _dispatch2("NN", a, g)
    return da.astype(a.dtype), db.astype(b.dtype)


_dispatch2.defvjp(_dispatch2_fwd, _dispatch2_bwd)


def _run3(op: str, a, b):
    """Select and execute one batched GEMM on (g, ., .) operands."""
    import jax.numpy as jnp

    g, m, k = a.shape
    n = b.shape[1] if op == "BNT" else b.shape[2]
    key = OpKey(
        op, int(m), int(n), int(k), int(jnp.dtype(a.dtype).itemsize), int(g)
    )
    decision = policy_select(current_policy(), key)
    return run_decision(key, decision, a, b)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _dispatch3(op: str, a, b):
    return _run3(op, a, b)


def _dispatch3_fwd(op: str, a, b):
    return _run3(op, a, b), (a, b)


def _dispatch3_bwd(op: str, res, g):
    """Batched backward rule: {BNT, BNN} is closed under differentiation
    modulo one explicit transpose of the cotangent/operand (a batched TN
    is a batched NN of the swapped operand) — every gradient of a batched
    dispatch is itself a policy-governed batched dispatch."""
    import jax.numpy as jnp

    a, b = res
    if op == "BNT":  # C_i = A_i B_i^T: dA_i = G_i @ B_i, dB_i = G_i^T @ A_i
        da = _dispatch3("BNN", g, b)
        db = _dispatch3("BNN", jnp.swapaxes(g, -1, -2), a)
    else:  # BNN, C_i = A_i B_i: dA_i = G_i @ B_i^T, dB_i = A_i^T @ G_i
        da = _dispatch3("BNT", g, b)
        db = _dispatch3("BNN", jnp.swapaxes(a, -1, -2), g)
    return da.astype(a.dtype), db.astype(b.dtype)


_dispatch3.defvjp(_dispatch3_fwd, _dispatch3_bwd)


# ---------------------------------------------------------------------------
# The fused-attention plan: one ATTN decision spanning the BNT+BNN pair.
# ---------------------------------------------------------------------------

# Finite masked-logit fill (mirrors kernels/attention_fused.NEG_INF):
# exp underflows to an exact 0.0 instead of producing inf - inf = nan.
_MASK_NEG = -1e30


def _attn_visibility(mask, lengths, m: int, n: int):
    """The (g, m, n) boolean visibility of ``MaskParams`` + the traced
    per-slice ``lengths`` — the jnp mirror of the in-kernel masking in
    ``kernels/attention_fused.py`` (same position arithmetic, so the
    fused and unfused plan arms agree bit-for-bit on *which* logits are
    masked)."""
    import jax.numpy as jnp

    rows = jnp.arange(m, dtype=jnp.int32)
    cols = jnp.arange(n, dtype=jnp.int32)
    q_seg = mask.q_seg if mask.q_seg else m
    q_pos = (mask.q_start + rows % q_seg)[None, :, None]  # (1, m, 1)
    k_pos = (mask.k_start + cols)[None, None, :]  # (1, 1, n)
    valid = cols[None, None, :] < lengths.reshape(-1, 1, 1)  # (g, 1, n)
    vis = valid
    if mask.causal:
        vis = vis & (k_pos <= q_pos)
    if mask.window:
        vis = vis & (k_pos > q_pos - mask.window)
    if mask.prefix_len:
        vis = vis | (valid & (k_pos < mask.prefix_len))
    return vis


def _attn_logits(q, k):
    """Raw f32 logits through the policy-dispatched batched GEMM — the
    unfused plan's first sub-op (a BNT OpKey at dsize 4, matching the
    model layer's pre-fusion upcast convention)."""
    import jax.numpy as jnp

    return _dispatch3(
        "BNT", q.astype(jnp.float32), k.astype(jnp.float32)
    ).astype(jnp.float32)


def _attn_probs(mask, s_raw, lengths):
    """f32 attention probabilities from raw logits: softcap, then the
    static+validity mask at a finite ``_MASK_NEG``, then softmax.  Fully
    masked columns come out exactly 0.0."""
    import jax.numpy as jnp

    m, n = s_raw.shape[-2:]
    s = s_raw
    if mask.softcap:
        cap = jnp.float32(mask.softcap)
        s = cap * jnp.tanh(s / cap)
    s = jnp.where(_attn_visibility(mask, lengths, m, n), s, _MASK_NEG)
    return jax.nn.softmax(s, axis=-1)


def _zero_invalid_kv(x, lengths):
    """Zero key/value rows beyond each slice's valid length — same
    poison hygiene as the fused kernel: an all-masked row's probs are
    not 0, so junk rows must not be summable (0 * nan = nan)."""
    import jax.numpy as jnp

    n = x.shape[1]
    valid = jnp.arange(n, dtype=jnp.int32)[None, :, None] < lengths.reshape(
        -1, 1, 1
    )
    return jnp.where(valid, x, 0)


def _unfused_attn_plan(mask, q, k, v, lengths):
    """The unfused plan arm: dispatched BNT logits -> softcap/mask/f32
    softmax -> dispatched BNN mix.  Each sub-GEMM goes through its own
    per-op policy decision, so forcing ``attn=unfused`` reproduces the
    pre-fusion dispatch behaviour exactly — this is also the fallback
    chain's terminal arm."""
    probs = _attn_probs(mask, _attn_logits(q, k), lengths)
    vz = _zero_invalid_kv(v, lengths)
    out = _dispatch3("BNN", probs.astype(v.dtype), vz)
    return out.astype(q.dtype)


def _run_attn(mask, q, k, v, lengths):
    """Select and execute the attention plan (the custom_vjp core).

    Mirrors ``run_decision`` — quarantine-skipped non-terminal arms,
    fault checks, fallback recording — but executes *plans* rather than
    ``Candidate.run``: ``FUSED_ATTN`` runs the flash kernel with the
    mask folded inside; every other arm (``UNFUSED_ATTN`` included)
    runs the unfused sub-dispatch plan."""
    import jax.numpy as jnp

    g, m, dh = q.shape
    n = k.shape[1]
    key = OpKey(
        "ATTN", int(m), int(n), int(dh),
        int(jnp.dtype(q.dtype).itemsize), int(g),
    )
    decision = policy_select(current_policy(), key)
    chain = _decision_chain("ATTN", decision)
    last_err: Optional[BaseException] = None
    for i, dec in enumerate(chain):
        terminal = i == len(chain) - 1
        if not terminal and faults.is_quarantined(dec.name, "ATTN", dec.config):
            continue
        try:
            faults.check_candidate_fault(dec.name, "ATTN")
            if dec.name == "FUSED_ATTN":
                from repro.kernels.attention_fused import attention_fused

                block = tuple(dec.config) if dec.config is not None else None
                out = attention_fused(q, k, v, lengths, mask=mask, block=block)
            else:
                out = _unfused_attn_plan(mask, q, k, v, lengths)
        except (KeyboardInterrupt, SystemExit):
            raise
        except Exception as e:
            faults.quarantine(dec.name, "ATTN", dec.config, e)
            _warn_once(
                f"quarantined:{dec.label()}:ATTN",
                f"candidate {dec.label()!r} failed on op 'ATTN' "
                f"({type(e).__name__}: {e}); quarantined for this process, "
                "dispatch degrades down the fallback chain",
            )
            last_err = e
            continue
        if (dec.name, dec.config) != (decision.name, decision.config):
            faults.record_fallback("ATTN", decision.label(), dec.label())
        return out
    raise DispatchError(
        f"every arm of the fallback chain for {key} failed: "
        f"{[d.label() for d in chain]}"
    ) from last_err


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _dispatch_attn(mask, q, k, v, lengths):
    return _run_attn(mask, q, k, v, lengths)


def _dispatch_attn_fwd(mask, q, k, v, lengths):
    # Flash-style residuals: operands only, never the (m, n) probs
    # matrix — the backward rule recomputes the softmax.
    return _run_attn(mask, q, k, v, lengths), (q, k, v, lengths)


def _dispatch_attn_bwd(mask, res, dout):
    """Flash backward: recompute the masked softmax from the saved
    operands, then take every gradient contraction through the batched
    dispatch — dQ/dK/dV land on policy-governed BNT/BNN OpKeys, same
    closure property as ``_dispatch3_bwd``.  ``lengths`` is integral:
    its cotangent is float0."""
    import jax.numpy as jnp

    q, k, v, lengths = res
    s_raw = _attn_logits(q, k)
    probs = _attn_probs(mask, s_raw, lengths)  # (g, m, n) f32
    dout32 = dout.astype(jnp.float32)
    # dV = P^T dO; masked probs are exactly 0 so invalid rows get 0.
    dv = _dispatch3("BNN", jnp.swapaxes(probs, -1, -2), dout32)
    # dP = dO V^T (V zeroed beyond lengths, as in the forward mix).
    dp = _dispatch3("BNT", dout32, _zero_invalid_kv(v, lengths).astype(jnp.float32))
    # softmax vjp: dS = P * (dP - sum(dP * P)); masked entries stay 0.
    ds = probs * (dp - jnp.sum(dp * probs, axis=-1, keepdims=True))
    if mask.softcap:
        cap = jnp.float32(mask.softcap)
        ds = ds * (1.0 - jnp.tanh(s_raw / cap) ** 2)
    k32 = k.astype(jnp.float32)
    q32 = q.astype(jnp.float32)
    dq = _dispatch3("BNN", ds, k32)
    dk = _dispatch3("BNN", jnp.swapaxes(ds, -1, -2), q32)
    dlen = np.zeros(lengths.shape, dtype=jax.dtypes.float0)
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype), dlen


_dispatch_attn.defvjp(_dispatch_attn_fwd, _dispatch_attn_bwd)


def dispatch_attention(
    q,
    k,
    v,
    *,
    lengths=None,
    causal: bool = False,
    window: int = 0,
    q_start: int = 0,
    k_start: int = 0,
    prefix_len: int = 0,
    q_seg: int = 0,
    softcap: float = 0.0,
    policy: Optional[SelectionPolicy] = None,
):
    """Compute the whole ``softmax(mask(Q K^T)) V`` subgraph through one
    policy-selected attention *plan*.

      dispatch_attention(q, k, v)   q:(..., m, dh) k/v:(..., n, dh) -> (..., m, dh)

    The leading axes of all three operands must match (broadcast K/V
    across the GQA group first, or fold the group into the row extent
    and pass ``q_seg``) and collapse to one batch extent ``g``; the
    policy sees ``OpKey("ATTN", m, n, dh, dsize, g)`` and answers with
    either the fused flash kernel (``FUSED_ATTN``, optionally at a
    learned ``(bq, bk)`` tile) or the unfused plan whose BNT/BNN
    sub-GEMMs are dispatched under their own per-op keys.

    Masking is part of the plan, not the caller: ``causal``, sliding
    ``window``, ``prefix_len`` (prefix-LM bidirectional span),
    ``q_start``/``k_start`` position offsets, ``q_seg`` (per-group query
    count after a group fold — row ``r`` sits at ``q_start + r % q_seg``)
    and per-slice valid-key ``lengths`` (shape matching the leading axes,
    default: all ``n`` keys valid).  ``softcap`` applies the model
    layer's ``cap * tanh(x / cap)`` logit cap before masking.  Queries
    are expected pre-scaled by ``d_head**-0.5``, same as the unfused
    convention.

    Differentiating re-enters dispatch: the backward rule recomputes the
    softmax flash-style (residuals are the operands, never the (m, n)
    probs matrix) and lands every gradient contraction on batched
    gradient OpKeys — wrap the whole ``value_and_grad`` call in one
    ``use_policy`` scope.
    """
    import jax.numpy as jnp
    from repro.kernels.attention_fused import MaskParams

    if policy is not None:
        with use_policy(policy):
            return dispatch_attention(
                q, k, v, lengths=lengths, causal=causal, window=window,
                q_start=q_start, k_start=k_start, prefix_len=prefix_len,
                q_seg=q_seg, softcap=softcap,
            )
    if q.ndim < 3 or k.ndim != q.ndim or v.ndim != q.ndim:
        raise ValueError(
            "dispatch_attention needs >= 3-D operands with matching "
            f"leading batch axes; got {q.shape}, {k.shape}, {v.shape}"
        )
    lead = q.shape[:-2]
    if k.shape[:-2] != lead or v.shape[:-2] != lead:
        raise ValueError(
            "dispatch_attention leading batch axes differ: "
            f"{q.shape} vs {k.shape} vs {v.shape} — broadcast K/V across "
            "the GQA group before dispatching"
        )
    if k.shape != v.shape or q.shape[-1] != k.shape[-1]:
        raise ValueError(
            "dispatch_attention operand extents mismatch: "
            f"{q.shape} vs {k.shape} vs {v.shape}"
        )
    q3 = q.reshape((-1,) + q.shape[-2:])
    k3 = k.reshape((-1,) + k.shape[-2:])
    v3 = v.reshape((-1,) + v.shape[-2:])
    g = q3.shape[0]
    n = k3.shape[1]
    if lengths is None:
        lengths3 = jnp.full((g, 1), n, jnp.int32)
    else:
        lengths3 = jnp.asarray(lengths, jnp.int32).reshape(g, 1)
    mask = MaskParams(
        causal=bool(causal),
        window=int(window or 0),
        q_start=int(q_start),
        k_start=int(k_start),
        prefix_len=int(prefix_len or 0),
        q_seg=int(q_seg or 0),
        softcap=float(softcap or 0.0),
    )
    out = _dispatch_attn(mask, q3, k3, v3, lengths3)
    return out.reshape(lead + out.shape[-2:])


def dispatch(op: str, a, b, policy: Optional[SelectionPolicy] = None):
    """Compute one dense-layer GEMM through the policy-selected
    (candidate, tile config).

      dispatch("NT", a, b)   a:(..., m, k) @ b:(n, k)^T -> (..., m, n)
      dispatch("NN", a, b)   a:(..., m, k) @ b:(k, n)   -> (..., m, n)
      dispatch("TN", a, b)   a:(k, m)^T    @ b:(k, n)   -> (m, n)

    ``a``/``b`` follow the op's storage layout (``core/opkey.py``): for NT,
    ``b`` is a weight in the paper's row-major (out, in) convention, so the
    forward pass of a dense layer is literally the paper's NT operation.
    Leading batch dims of ``a`` are flattened for NT/NN (TN contracts the
    leading dim, so it is strictly 2-D).  The batched BNT/BNN ops go
    through ``dispatch_batched``.

    Differentiating through ``dispatch`` re-enters it: the backward data
    and weight gradients are dispatched as NN/TN OpKeys under the policy
    in scope at *backward-trace* time — wrap the whole ``value_and_grad``
    call in ``use_policy(...)`` so one scope governs all three GEMMs.

    An explicit ``policy=`` scopes only this call's forward selection
    (prefer ``use_policy`` around the full computation).
    """
    check_op(op)
    if op == "ATTN":
        raise ValueError(
            "op 'ATTN' is the attention plan; call "
            "dispatch_attention(q, k, v, ...)"
        )
    if op in BATCHED_OPS:
        raise ValueError(
            f"op {op!r} is batched; call dispatch_batched({op!r}, a, b)"
        )
    if policy is not None:
        with use_policy(policy):
            return dispatch(op, a, b)
    if op == "TN":
        return _dispatch2("TN", a, b)
    lead = a.shape[:-1]
    a2 = a.reshape((-1, a.shape[-1]))
    out = _dispatch2(op, a2, b)
    n = b.shape[0] if op == "NT" else b.shape[1]
    return out.reshape(lead + (n,))


def dispatch_batched(op: str, a, b, policy: Optional[SelectionPolicy] = None):
    """Compute one batched GEMM — the attention contractions — through the
    policy-selected (candidate, tile config).

      dispatch_batched("BNT", a, b)  a:(..., m, k) @ b:(..., n, k)^T -> (..., m, n)
      dispatch_batched("BNN", a, b)  a:(..., m, k) @ b:(..., k, n)   -> (..., m, n)

    The leading axes of ``a`` and ``b`` must match (broadcast K/V across
    the GQA group *before* dispatching) and collapse to one batch extent
    ``g`` — the ``OpKey`` the policy sees is ``(op, m, n, k, dsize, g)``,
    with (m, n, k) the per-slice extents.  Differentiating re-enters
    dispatch with batched gradient OpKeys, same contract as ``dispatch``:
    wrap the whole ``value_and_grad`` call in one ``use_policy`` scope.
    """
    check_op(op)
    if op == "ATTN":
        raise ValueError(
            "op 'ATTN' is the attention plan; call "
            "dispatch_attention(q, k, v, ...)"
        )
    if op not in BATCHED_OPS:
        raise ValueError(
            f"op {op!r} is not batched; call dispatch({op!r}, a, b)"
        )
    if policy is not None:
        with use_policy(policy):
            return dispatch_batched(op, a, b)
    if a.ndim < 3 or b.ndim != a.ndim:
        raise ValueError(
            f"dispatch_batched({op!r}) needs >= 3-D operands with matching "
            f"leading batch axes; got {a.shape} and {b.shape}"
        )
    lead = a.shape[:-2]
    if b.shape[:-2] != lead:
        raise ValueError(
            f"dispatch_batched({op!r}) leading batch axes differ: "
            f"{a.shape} vs {b.shape} — broadcast the operands first"
        )
    a3 = a.reshape((-1,) + a.shape[-2:])
    b3 = b.reshape((-1,) + b.shape[-2:])
    out = _dispatch3(op, a3, b3)
    return out.reshape(lead + out.shape[-2:])


def dispatch_report(policy: Optional[SelectionPolicy] = None) -> str:
    """Pretty-print per-(op, candidate, tile-config) decision counts for
    ``policy`` (default: the scoped policy).  Rows are grouped by op kind
    and keyed ``NAME@BMxBNxBK`` for decisions that carried an explicit tile
    (``NAME`` for kernel-default ones), so backward-GEMM and attention
    routing is visible in production logs.  Returns the rendered table;
    callers print it."""
    pol = policy if policy is not None else current_policy()
    stats = pol.stats
    lines = [f"dispatch report — {pol!r}"]
    quarantined = faults.quarantine_entries()
    if quarantined:
        lines.append(
            f"  quarantined arms: {len(quarantined)} "
            f"({', '.join(e.label() for e in quarantined)}) — see "
            "health_report()"
        )
    if not stats.calls:
        lines.append("  (no dispatches recorded)")
        return "\n".join(lines)
    by_op = getattr(stats, "by_op", None)
    if by_op:
        rows = [
            (op, label, count)
            for op, labels in by_op.items()
            for label, count in labels.items()
        ]
    else:
        # stats objects predating the op split: one unlabelled group
        flat = getattr(stats, "by_decision", None) or stats.by_candidate
        rows = [("-", label, count) for label, count in flat.items()]
    width = max(len("candidate[@tile]"), max(len(label) for _, label, _ in rows))
    lines.append(
        f"  {'op':<4s} {'candidate[@tile]':<{width}s} {'calls':>8s} {'share':>7s}"
    )
    op_order = {op: i for i, op in enumerate(OPS)}
    rows.sort(key=lambda r: (op_order.get(r[0], 99), -r[2], r[1]))
    for op, label, count in rows:
        lines.append(
            f"  {op:<4s} {label:<{width}s} {count:8d} "
            f"{100.0 * count / stats.calls:6.1f}%"
        )
    lines.append(f"  {'':<4s} {'total':<{width}s} {stats.calls:8d}")
    return "\n".join(lines)


def health_report() -> str:
    """Render the process-wide dispatch health: armed fault-injection
    rules, the quarantine ledger (which arms failed, how, how often), and
    the fallbacks taken — the operator's view of graceful degradation.
    Returns the rendered text; callers print it."""
    lines = ["health report — dispatch fault tolerance"]
    rules = faults.active_faults()
    if rules:
        lines.append(f"  fault injection: {len(rules)} armed rule(s)")
        for rule in rules:
            lines.append(f"    {rule.describe()}")
    else:
        lines.append("  fault injection: (none armed)")
    entries = faults.quarantine_entries()
    if entries:
        lines.append(f"  quarantined arms: {len(entries)}")
        for e in entries:
            lines.append(
                f"    {e.op:<4s} {e.label():<24s} failures={e.count} "
                f"[{e.error}]"
            )
    else:
        lines.append("  quarantined arms: (none)")
    fallbacks = faults.fallback_counts()
    if fallbacks:
        total = sum(fallbacks.values())
        lines.append(f"  fallbacks taken: {total}")
        for (op, selected, executed), n in sorted(fallbacks.items()):
            lines.append(f"    {op:<4s} {selected} -> {executed} x{n}")
    else:
        lines.append("  fallbacks taken: (none)")
    return "\n".join(lines)


def _parse_fixed_arg(arg: str) -> FixedPolicy:
    """``fixed:`` spec bodies — either a single candidate or an
    op-qualified table (``nt=XLA_NT,bnt=PALLAS_BNT@128x128x128,``
    ``attn=fused@128x256``).  The ``attn=`` entry accepts the plan
    aliases ``fused``/``unfused`` alongside literal candidate names, and
    every config parses at its candidate's declared arity — ``BQxBK``
    for the fused attention kernel, ``BMxBNxBK`` for the matmul tiles."""
    from repro.kernels.tiling import parse_config_key

    def parse_entry(val: str, op: Optional[str] = None):
        name, _, cfg = val.partition("@")
        name = name.strip()
        if op == "ATTN":
            name = _ATTN_ALIASES.get(name.lower(), name)
        config = None
        if cfg.strip():
            try:
                arity = get_candidate(name).config_arity
            except KeyError:
                arity = 3
            try:
                config = parse_config_key(cfg.strip(), arity=arity)
            except ValueError as e:
                raise _spec_error(str(e))
        return name, config

    if "=" not in arg:
        name, config = parse_entry(arg)
        return FixedPolicy(name, config=config)
    by_op = {}
    for part in arg.split(","):
        part = part.strip()
        if not part:
            continue
        op_s, eq, val = part.partition("=")
        op = op_s.strip().upper()
        if not eq or op not in OPS or not val.strip():
            raise _spec_error(
                f"malformed op-qualified fixed entry {part!r}; expected "
                "nt=<NAME>[@BMxBNxBK] with op in nt/nn/tn/bnt/bnn/attn"
            )
        by_op[op] = parse_entry(val, op=op)
    if not by_op:
        raise _spec_error("fixed policy needs at least one op entry")
    return FixedPolicy(by_op=by_op)


def policy_from_spec(spec: str, distributed: bool = False) -> SelectionPolicy:
    """Build a policy from a CLI-friendly spec string.

      model[:path]              learned selector (default artifact or path)
      fixed:XLA_TNN             FixedPolicy (other ops — backward GEMMs,
                                attention contractions — run each op's
                                XLA reference)
      fixed:PALLAS_NT@256x256x512   FixedPolicy with a forced tile config
      fixed:nt=XLA_NT,nn=PALLAS_NN[@BMxBNxBK],tn=XLA_TN,bnt=PALLAS_BNT,bnn=XLA_BNN
                                op-qualified FixedPolicy: force a
                                (candidate, tile) per op kind
      fixed:attn=fused@128x256  attention-plan entry: ``fused``/``unfused``
                                alias the FUSED_ATTN/UNFUSED_ATTN pair;
                                fused tiles are (bq, bk)
      analytic                  AnalyticPolicy on the default hardware
      cascade:A,B,C             CascadePolicy over the named candidates
      autotune[:cache.json]     AutotunePolicy over the (op, candidate,
                                tile) measurement cache
                                (default: core.measure.default_cache_path())

    Whitespace around the kind and its argument is ignored, so quoted CLI
    values like ``--policy "fixed: XLA_NT"`` parse.  ``distributed=True``
    restricts guarded policies to pjit-safe candidates — launchers running
    on a >1-device mesh must pass it (FixedPolicy is exempt: forcing a
    candidate is an explicit user override) — and disables autotune
    measurement (cached timings are still used).
    """
    kind, _, arg = spec.strip().partition(":")
    kind = kind.strip()
    arg = arg.strip()
    if not kind:
        raise _spec_error("empty policy spec")
    if kind == "model":
        if not arg:
            return default_policy()  # builtin selector: distributed-safe
        # recover=True: the CLI is the production path — a corrupt artifact
        # is moved aside and a fallback selector trained, never a crash
        return ModelPolicy.from_artifact(
            arg, distributed=distributed, recover=True
        )
    if kind == "fixed":
        if not arg:
            raise _spec_error("fixed policy needs a candidate: fixed:<NAME>")
        return _parse_fixed_arg(arg)
    if kind == "analytic":
        return AnalyticPolicy(distributed=distributed)
    if kind == "autotune":
        from .measure import default_cache_path

        return AutotunePolicy(
            cache_path=arg or default_cache_path(), distributed=distributed
        )
    if kind == "cascade":
        names = [n.strip() for n in arg.split(",") if n.strip()]
        if not names:
            raise _spec_error("cascade policy needs names: cascade:<A,B,...>")
        return CascadePolicy(names, distributed=distributed)
    raise _spec_error(f"unknown policy spec {spec!r}")


def add_policy_argument(parser) -> None:
    """Attach the shared ``--policy`` option to an argparse parser."""
    parser.add_argument("--policy", default="model", help=POLICY_SPEC_HELP)
