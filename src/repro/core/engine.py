"""The dispatch engine: every NT op in the model layer lands here.

``dispatch_nt(a, b)`` computes ``a @ b^T`` through whichever
*(candidate, tile config)* the scoped policy picks
(``policy.current_policy()``) — model code never threads a selector
argument.  Because JAX shapes are static under ``jit``, the policy runs
once per distinct shape at trace time and contributes nothing to the
compiled step.

``dispatch_report()`` renders the per-(candidate, config) decision counts
of the scoped policy — surfaced at the end of train/serve runs so dispatch
stays observable in production.
"""

from __future__ import annotations

from typing import Optional

from .candidates import get_candidate
from .policy import (
    AnalyticPolicy,
    AutotunePolicy,
    CascadePolicy,
    Decision,
    FixedPolicy,
    ModelPolicy,
    SelectionPolicy,
    current_policy,
    default_policy,
    use_policy,
)

__all__ = [
    "dispatch_nt",
    "dispatch_report",
    "policy_from_spec",
    "add_policy_argument",
    "use_policy",
    "current_policy",
    "default_policy",
]

POLICY_SPEC_HELP = (
    "NT-dispatch policy: model[:artifact.json] | fixed:<NAME>[@BMxBNxBK] | "
    "analytic | cascade:<A,B,...> | autotune[:cache.json]"
)


def _spec_error(msg: str) -> ValueError:
    """Every malformed spec gets the same actionable hint."""
    return ValueError(f"{msg} ({POLICY_SPEC_HELP})")


def dispatch_nt(a, b, policy: Optional[SelectionPolicy] = None):
    """Compute ``a @ b^T`` through the policy-selected (candidate, config).

    ``a``: (..., m, k) activations; ``b``: (n, k) weights in the paper's
    row-major (out, in) convention — the forward pass of a dense layer is
    literally the paper's NT operation.
    """
    import jax.numpy as jnp

    pol = policy if policy is not None else current_policy()
    lead = a.shape[:-1]
    k = a.shape[-1]
    n = b.shape[0]
    m = 1
    for d in lead:
        m *= int(d)
    decision = pol.select(m, n, k, dsize=jnp.dtype(a.dtype).itemsize)
    if isinstance(decision, str):  # legacy/third-party policy: bare name
        decision = Decision(decision, None)
    a2 = a.reshape((m, k))
    out = get_candidate(decision.name).run(a2, b, decision.config)
    return out.reshape(lead + (n,))


def dispatch_report(policy: Optional[SelectionPolicy] = None) -> str:
    """Pretty-print per-(candidate, tile-config) decision counts for
    ``policy`` (default: the scoped policy).  Rows are keyed
    ``NAME@BMxBNxBK`` for decisions that carried an explicit tile and
    ``NAME`` for kernel-default ones.  Returns the rendered table; callers
    print it."""
    pol = policy if policy is not None else current_policy()
    stats = pol.stats
    lines = [f"dispatch report — {pol!r}"]
    if not stats.calls:
        lines.append("  (no dispatches recorded)")
        return "\n".join(lines)
    # by_decision carries the (candidate, config) split; fall back to the
    # plain per-candidate counts for stats objects that lack it
    rows = getattr(stats, "by_decision", None) or stats.by_candidate
    width = max(len("candidate[@tile]"), max(len(n) for n in rows))
    lines.append(f"  {'candidate[@tile]':<{width}s} {'calls':>8s} {'share':>7s}")
    for name, count in sorted(rows.items(), key=lambda kv: -kv[1]):
        lines.append(
            f"  {name:<{width}s} {count:8d} {100.0 * count / stats.calls:6.1f}%"
        )
    lines.append(f"  {'total':<{width}s} {stats.calls:8d}")
    return "\n".join(lines)


def policy_from_spec(spec: str, distributed: bool = False) -> SelectionPolicy:
    """Build a policy from a CLI-friendly spec string.

      model[:path]              learned selector (default artifact or path)
      fixed:XLA_TNN             FixedPolicy
      fixed:PALLAS_NT@256x256x512   FixedPolicy with a forced tile config
      analytic                  AnalyticPolicy on the default hardware
      cascade:A,B,C             CascadePolicy over the named candidates
      autotune[:cache.json]     AutotunePolicy over the (candidate, tile)
                                measurement cache
                                (default: core.measure.default_cache_path())

    Whitespace around the kind and its argument is ignored, so quoted CLI
    values like ``--policy "fixed: XLA_NT"`` parse.  ``distributed=True``
    restricts guarded policies to pjit-safe candidates — launchers running
    on a >1-device mesh must pass it (FixedPolicy is exempt: forcing a
    candidate is an explicit user override) — and disables autotune
    measurement (cached timings are still used).
    """
    kind, _, arg = spec.strip().partition(":")
    kind = kind.strip()
    arg = arg.strip()
    if not kind:
        raise _spec_error("empty policy spec")
    if kind == "model":
        if not arg:
            return default_policy()  # builtin selector: distributed-safe
        return ModelPolicy.from_artifact(arg, distributed=distributed)
    if kind == "fixed":
        if not arg:
            raise _spec_error("fixed policy needs a candidate: fixed:<NAME>")
        name, _, cfg = arg.partition("@")
        config = None
        if cfg.strip():
            from repro.kernels.tiling import parse_config_key

            try:
                config = parse_config_key(cfg.strip())
            except ValueError as e:
                raise _spec_error(str(e))
        return FixedPolicy(name.strip(), config=config)
    if kind == "analytic":
        return AnalyticPolicy(distributed=distributed)
    if kind == "autotune":
        from .measure import default_cache_path

        return AutotunePolicy(
            cache_path=arg or default_cache_path(), distributed=distributed
        )
    if kind == "cascade":
        names = [n.strip() for n in arg.split(",") if n.strip()]
        if not names:
            raise _spec_error("cascade policy needs names: cascade:<A,B,...>")
        return CascadePolicy(names, distributed=distributed)
    raise _spec_error(f"unknown policy spec {spec!r}")


def add_policy_argument(parser) -> None:
    """Attach the shared ``--policy`` option to an argparse parser."""
    parser.add_argument("--policy", default="model", help=POLICY_SPEC_HELP)
