"""The dispatch engine: every dense-layer GEMM in the model layer lands here.

``dispatch(op, a, b)`` computes one of the three training GEMMs —
``"NT"`` (``a @ b^T``), ``"NN"`` (``a @ b``) or ``"TN"`` (``a^T @ b``) —
through whichever *(candidate, tile config)* the scoped policy picks for
the ``OpKey`` (``policy.current_policy()``); model code never threads a
selector argument.  Because JAX shapes are static under ``jit``, the
policy runs once per distinct key at trace time and contributes nothing
to the compiled step.

``dispatch`` is ``custom_vjp``-wrapped: its backward rule rebuilds the
NN/TN (data/weight-gradient) OpKeys and re-enters dispatch, so a single
``use_policy(...)`` scope governs all three GEMMs of every dense layer in
train *and* serve — the paper's end-to-end training speedup depends on the
backward ops being routed too.  Selection happens at trace time, so the
scope must wrap the whole ``value_and_grad`` call (forward *and* backward
trace), not just the forward pass.

``dispatch_nt(a, b)`` is the pre-op-space entry point, kept as a thin
compatibility wrapper (it warns once); new code should call
``dispatch("NT", a, b)``.

``dispatch_report()`` renders the per-(op, candidate, config) decision
counts of the scoped policy — surfaced at the end of train/serve runs so
dispatch stays observable in production.
"""

from __future__ import annotations

import functools
import inspect
import warnings
from typing import Optional

import jax

from .candidates import DEFAULT_BY_OP, get_candidate
from .opkey import OPS, OpKey, check_op
from .policy import (
    AnalyticPolicy,
    AutotunePolicy,
    CascadePolicy,
    Decision,
    FixedPolicy,
    ModelPolicy,
    SelectionPolicy,
    current_policy,
    default_policy,
    use_policy,
)

__all__ = [
    "dispatch",
    "dispatch_nt",
    "dispatch_report",
    "policy_select",
    "policy_from_spec",
    "add_policy_argument",
    "use_policy",
    "current_policy",
    "default_policy",
]

POLICY_SPEC_HELP = (
    "dispatch policy: model[:artifact.json] | fixed:<NAME>[@BMxBNxBK] | "
    "fixed:nt=<NAME>[@cfg],nn=<NAME>[@cfg],tn=<NAME>[@cfg] | analytic | "
    "cascade:<A,B,...> | autotune[:cache.json]"
)

_WARNED: set = set()


def _warn_once(tag: str, msg: str) -> None:
    if tag not in _WARNED:
        _WARNED.add(tag)
        warnings.warn(msg, DeprecationWarning, stacklevel=3)


def _spec_error(msg: str) -> ValueError:
    """Every malformed spec gets the same actionable hint."""
    return ValueError(f"{msg} ({POLICY_SPEC_HELP})")


# Legacy-signature detection is per *class* (a class's select signature
# does not change), so the hot dispatch path never pays reflection twice.
_LEGACY_SELECT_BY_TYPE: dict = {}


def _has_legacy_select(policy: SelectionPolicy) -> bool:
    cls = type(policy)
    cached = _LEGACY_SELECT_BY_TYPE.get(cls)
    if cached is None:
        cached = False
        try:
            params = list(inspect.signature(policy.select).parameters)
            cached = bool(params) and params[0] == "m"
        except (TypeError, ValueError):
            pass
        _LEGACY_SELECT_BY_TYPE[cls] = cached
    return cached


def policy_select(policy: SelectionPolicy, key: OpKey) -> Decision:
    """Run ``policy.select`` on an ``OpKey`` — the one place the
    deprecation shims live:

      * legacy policies whose ``select(m, n, k, dsize)`` takes positional
        shape ints (detected by signature, cached per class) are called
        that way — but only for the forward op, which is all the
        positional form could ever express; backward NN/TN keys degrade to
        the op's reference candidate instead of handing a legacy policy an
        op it cannot see (its NT answer would run on wrong-layout
        operands);
      * bare-string decisions (a candidate name instead of a ``Decision``)
        are normalised to ``Decision(name, None)``;
      * a decision naming a candidate that does not implement ``key.op``
        (a mis-op'd policy) degrades to the op's reference rather than
        executing a kernel on operands in the wrong storage layout.

    The adaptations warn once per process; the legacy shims will be
    removed after one release.
    """
    if _has_legacy_select(policy):
        _warn_once(
            "legacy-select",
            "policies with a positional select(m, n, k, dsize) signature are "
            "deprecated; take an OpKey (op, m, n, k, dsize) instead so "
            "backward NN/TN GEMMs can be routed",
        )
        if key.op != "NT":
            # the positional API predates the op space: this policy cannot
            # answer for a backward GEMM, so run the op's reference
            return Decision(DEFAULT_BY_OP[key.op], None)
        decision = policy.select(key.m, key.n, key.k, dsize=key.dsize)
    else:
        decision = policy.select(key)
    if isinstance(decision, str):  # legacy/third-party policy: bare name
        _warn_once(
            "bare-string-decision",
            "policies returning a bare candidate name are deprecated; return "
            "a Decision(name, config)",
        )
        decision = Decision(decision, None)
    if key.op not in get_candidate(decision.name).ops:
        _warn_once(
            "op-mismatched-decision",
            f"policy {policy!r} returned candidate {decision.name!r} for an "
            f"op it does not implement; dispatching the op's reference "
            "instead",
        )
        decision = Decision(DEFAULT_BY_OP[key.op], None)
    return decision


def _run(op: str, a, b):
    """Select and execute one 2-D GEMM (the custom_vjp core)."""
    import jax.numpy as jnp

    if op == "NT":  # a:(m,k) b:(n,k)
        m, k = a.shape
        n = b.shape[0]
    elif op == "NN":  # a:(m,k) b:(k,n)
        m, k = a.shape
        n = b.shape[1]
    else:  # TN: a:(k,m) b:(k,n)
        k, m = a.shape
        n = b.shape[1]
    key = OpKey(op, int(m), int(n), int(k), int(jnp.dtype(a.dtype).itemsize))
    decision = policy_select(current_policy(), key)
    return get_candidate(decision.name).run(a, b, decision.config)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _dispatch2(op: str, a, b):
    return _run(op, a, b)


def _dispatch2_fwd(op: str, a, b):
    return _run(op, a, b), (a, b)


def _dispatch2_bwd(op: str, res, g):
    """Backward rule: each gradient GEMM is itself a dispatch — the op
    space {NT, NN, TN} is closed under differentiation, so both gradients
    of every op land back on a policy-governed op.  (First-order reverse
    mode only: custom_vjp does not support forward-mode/higher-order.)"""
    a, b = res
    if op == "NT":  # C = A B^T: dA = G @ B (NN), dB = G^T @ A (TN)
        da = _dispatch2("NN", g, b)
        db = _dispatch2("TN", g, a)
    elif op == "NN":  # C = A B: dA = G @ B^T (NT), dB = A^T @ G (TN)
        da = _dispatch2("NT", g, b)
        db = _dispatch2("TN", a, g)
    else:  # TN, C = A^T B: dA = B @ G^T (NT), dB = A @ G (NN)
        da = _dispatch2("NT", b, g)
        db = _dispatch2("NN", a, g)
    return da.astype(a.dtype), db.astype(b.dtype)


_dispatch2.defvjp(_dispatch2_fwd, _dispatch2_bwd)


def dispatch(op: str, a, b, policy: Optional[SelectionPolicy] = None):
    """Compute one dense-layer GEMM through the policy-selected
    (candidate, tile config).

      dispatch("NT", a, b)   a:(..., m, k) @ b:(n, k)^T -> (..., m, n)
      dispatch("NN", a, b)   a:(..., m, k) @ b:(k, n)   -> (..., m, n)
      dispatch("TN", a, b)   a:(k, m)^T    @ b:(k, n)   -> (m, n)

    ``a``/``b`` follow the op's storage layout (``core/opkey.py``): for NT,
    ``b`` is a weight in the paper's row-major (out, in) convention, so the
    forward pass of a dense layer is literally the paper's NT operation.
    Leading batch dims of ``a`` are flattened for NT/NN (TN contracts the
    leading dim, so it is strictly 2-D).

    Differentiating through ``dispatch`` re-enters it: the backward data
    and weight gradients are dispatched as NN/TN OpKeys under the policy
    in scope at *backward-trace* time — wrap the whole ``value_and_grad``
    call in ``use_policy(...)`` so one scope governs all three GEMMs.

    An explicit ``policy=`` scopes only this call's forward selection
    (prefer ``use_policy`` around the full computation).
    """
    check_op(op)
    if policy is not None:
        with use_policy(policy):
            return dispatch(op, a, b)
    if op == "TN":
        return _dispatch2("TN", a, b)
    lead = a.shape[:-1]
    a2 = a.reshape((-1, a.shape[-1]))
    out = _dispatch2(op, a2, b)
    n = b.shape[0] if op == "NT" else b.shape[1]
    return out.reshape(lead + (n,))


def dispatch_nt(a, b, policy: Optional[SelectionPolicy] = None):
    """Deprecated pre-op-space entry point: ``dispatch("NT", a, b)``.

    Kept as a thin compatibility wrapper so existing callers keep working
    — and, unlike the pre-redesign engine, gradients taken through it now
    route the backward NN/TN GEMMs through the policy too instead of
    silently diverging to whatever XLA derives.  Warns once per process.
    """
    _warn_once(
        "dispatch_nt",
        "dispatch_nt(a, b) is deprecated; call dispatch('NT', a, b) — the "
        "op-space entry point whose backward also dispatches the NN/TN "
        "gradient GEMMs",
    )
    return dispatch("NT", a, b, policy=policy)


def dispatch_report(policy: Optional[SelectionPolicy] = None) -> str:
    """Pretty-print per-(op, candidate, tile-config) decision counts for
    ``policy`` (default: the scoped policy).  Rows are grouped by op kind
    and keyed ``NAME@BMxBNxBK`` for decisions that carried an explicit tile
    (``NAME`` for kernel-default ones), so backward-GEMM routing is visible
    in production logs.  Returns the rendered table; callers print it."""
    pol = policy if policy is not None else current_policy()
    stats = pol.stats
    lines = [f"dispatch report — {pol!r}"]
    if not stats.calls:
        lines.append("  (no dispatches recorded)")
        return "\n".join(lines)
    by_op = getattr(stats, "by_op", None)
    if by_op:
        rows = [
            (op, label, count)
            for op, labels in by_op.items()
            for label, count in labels.items()
        ]
    else:
        # stats objects predating the op split: one unlabelled group
        flat = getattr(stats, "by_decision", None) or stats.by_candidate
        rows = [("-", label, count) for label, count in flat.items()]
    width = max(len("candidate[@tile]"), max(len(label) for _, label, _ in rows))
    lines.append(
        f"  {'op':<3s} {'candidate[@tile]':<{width}s} {'calls':>8s} {'share':>7s}"
    )
    op_order = {op: i for i, op in enumerate(OPS)}
    rows.sort(key=lambda r: (op_order.get(r[0], 99), -r[2], r[1]))
    for op, label, count in rows:
        lines.append(
            f"  {op:<3s} {label:<{width}s} {count:8d} "
            f"{100.0 * count / stats.calls:6.1f}%"
        )
    lines.append(f"  {'':<3s} {'total':<{width}s} {stats.calls:8d}")
    return "\n".join(lines)


def _parse_fixed_arg(arg: str) -> FixedPolicy:
    """``fixed:`` spec bodies — either a single candidate or an
    op-qualified table (``nt=XLA_NT,nn=PALLAS_NN@128x128x128``)."""
    from repro.kernels.tiling import parse_config_key

    def parse_entry(val: str):
        name, _, cfg = val.partition("@")
        config = None
        if cfg.strip():
            try:
                config = parse_config_key(cfg.strip())
            except ValueError as e:
                raise _spec_error(str(e))
        return name.strip(), config

    if "=" not in arg:
        name, config = parse_entry(arg)
        return FixedPolicy(name, config=config)
    by_op = {}
    for part in arg.split(","):
        part = part.strip()
        if not part:
            continue
        op_s, eq, val = part.partition("=")
        op = op_s.strip().upper()
        if not eq or op not in OPS or not val.strip():
            raise _spec_error(
                f"malformed op-qualified fixed entry {part!r}; expected "
                "nt=<NAME>[@BMxBNxBK] with op in nt/nn/tn"
            )
        by_op[op] = parse_entry(val)
    if not by_op:
        raise _spec_error("fixed policy needs at least one op entry")
    return FixedPolicy(by_op=by_op)


def policy_from_spec(spec: str, distributed: bool = False) -> SelectionPolicy:
    """Build a policy from a CLI-friendly spec string.

      model[:path]              learned selector (default artifact or path)
      fixed:XLA_TNN             FixedPolicy (backward GEMMs run each op's
                                XLA reference)
      fixed:PALLAS_NT@256x256x512   FixedPolicy with a forced tile config
      fixed:nt=XLA_NT,nn=PALLAS_NN[@BMxBNxBK],tn=XLA_TN
                                op-qualified FixedPolicy: force a
                                (candidate, tile) per op kind
      analytic                  AnalyticPolicy on the default hardware
      cascade:A,B,C             CascadePolicy over the named candidates
      autotune[:cache.json]     AutotunePolicy over the (op, candidate,
                                tile) measurement cache
                                (default: core.measure.default_cache_path())

    Whitespace around the kind and its argument is ignored, so quoted CLI
    values like ``--policy "fixed: XLA_NT"`` parse.  ``distributed=True``
    restricts guarded policies to pjit-safe candidates — launchers running
    on a >1-device mesh must pass it (FixedPolicy is exempt: forcing a
    candidate is an explicit user override) — and disables autotune
    measurement (cached timings are still used).
    """
    kind, _, arg = spec.strip().partition(":")
    kind = kind.strip()
    arg = arg.strip()
    if not kind:
        raise _spec_error("empty policy spec")
    if kind == "model":
        if not arg:
            return default_policy()  # builtin selector: distributed-safe
        return ModelPolicy.from_artifact(arg, distributed=distributed)
    if kind == "fixed":
        if not arg:
            raise _spec_error("fixed policy needs a candidate: fixed:<NAME>")
        return _parse_fixed_arg(arg)
    if kind == "analytic":
        return AnalyticPolicy(distributed=distributed)
    if kind == "autotune":
        from .measure import default_cache_path

        return AutotunePolicy(
            cache_path=arg or default_cache_path(), distributed=distributed
        )
    if kind == "cascade":
        names = [n.strip() for n in arg.split(",") if n.strip()]
        if not names:
            raise _spec_error("cascade policy needs names: cascade:<A,B,...>")
        return CascadePolicy(names, distributed=distributed)
    raise _spec_error(f"unknown policy spec {spec!r}")


def add_policy_argument(parser) -> None:
    """Attach the shared ``--policy`` option to an argparse parser."""
    parser.add_argument("--policy", default="model", help=POLICY_SPEC_HELP)
