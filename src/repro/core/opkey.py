"""The op-space selection key: *(op kind x batch x shape x dtype width)*.

The paper's 28% end-to-end speedup comes from routing the *training*
GEMMs — the forward NT plus the backward data/weight gradients — through
learned selection.  Those three matmuls of a dense layer are distinct
*operations*, not just distinct shapes:

  NT   C = A @ B^T    A:(m, k)  B:(n, k)   forward of a (out, in) dense
  NN   C = A @ B      A:(m, k)  B:(k, n)   data gradient  dX = dY @ W
  TN   C = A^T @ B    A:(k, m)  B:(k, n)   weight gradient dW = dY^T @ X

The attention contractions widen the space to *batched* GEMMs — cuDNN's
canonical attention primitive (batched-strided GEMM) — with one extra
extent ``g``, the collapsed product of the leading batch/head axes:

  BNT  C_i = A_i @ B_i^T  A:(g, m, k)  B:(g, n, k)   Q @ K^T logits
  BNN  C_i = A_i @ B_i    A:(g, m, k)  B:(g, k, n)   probs @ V

``OpKey`` names one dispatch decision point: which op, at which batch
extent ``g`` (1 for the unbatched ops), at which logical (m, n, k) —
m/n are the per-slice output extents, k the contraction — and at which
element size.  Every ``SelectionPolicy.select`` takes an ``OpKey`` and the
whole persistence stack (measurement caches, selector artifacts, dispatch
reports) is keyed by it, so the selection space is genuinely
*(op x batch x shape x tile config)* — the same generalization AutoTVM
made from per-kernel to per-operator learned cost models.

The legacy positional ``select(m, n, k, dsize)`` form was removed after
its one-release deprecation cycle: ``coerce_key`` now accepts only an
``OpKey`` and raises a clean ``TypeError`` otherwise.
"""

from __future__ import annotations

from typing import NamedTuple, Sequence, Tuple

__all__ = [
    "OPS",
    "BATCHED_OPS",
    "GROUPED_OPS",
    "OpKey",
    "check_op",
    "coerce_key",
    "shape_key",
    "parse_shape_key",
]

# The op kinds of the dense layer's training GEMMs plus the batched
# attention contractions.  Closed under differentiation:
# d(NT) -> {NN, TN}, d(NN) -> {NT, TN}, d(TN) -> {NT, NN}, and — with an
# explicit transpose of one operand — d(BNT) -> {BNN}, d(BNN) -> {BNT,
# BNN}; this is what lets the dispatch engine's custom_vjp re-enter
# itself for both the 2-D and the batched entry points.
#
# ATTN is the first *subgraph* op: the whole ``softmax(Q K^T) V`` chain
# as one decision point (the ROADMAP's stepping stone from per-op
# Decisions to whole-block Plans).  Its extents read per slice: m
# queries, n keys, k the head dim; ``g`` the collapsed (batch x kv-head)
# axis.  d(ATTN) -> {BNT, BNN}: the flash backward recomputes the
# softmax and re-enters dispatch through the batched GEMM ops.
OPS: Tuple[str, ...] = ("NT", "NN", "TN", "BNT", "BNN", "ATTN")

# The subset with a leading batch axis (attention contractions).
BATCHED_OPS: Tuple[str, ...] = ("BNT", "BNN")

# The ops whose OpKey carries a meaningful batch extent g: the batched
# GEMMs plus the attention subgraph op (three (g, ., .) operands).
GROUPED_OPS: Tuple[str, ...] = BATCHED_OPS + ("ATTN",)


def check_op(op: str) -> str:
    if op not in OPS:
        raise ValueError(f"unknown op kind {op!r}; expected one of {OPS}")
    return op


class OpKey(NamedTuple):
    """One dispatch decision point: op kind, per-slice output/contraction
    extents, element size, and — for the batched BNT/BNN ops — the
    collapsed batch extent ``g``.  ``m``/``n`` are the *output* dims and
    ``k`` the contraction regardless of op, so (m, n, k) reads the same
    way for every op (the storage layouts differ, see module docstring).
    ``g`` is 1 for the unbatched NT/NN/TN ops."""

    op: str
    m: int
    n: int
    k: int
    dsize: int = 4
    g: int = 1

    def mnk(self) -> Tuple[int, int, int]:
        return (self.m, self.n, self.k)


def coerce_key(key) -> OpKey:
    """Normalise a ``select`` argument to a validated ``OpKey``.

    Only the op-space API is accepted; the legacy positional
    ``select(m, n, k[, dsize])`` form was removed after its deprecation
    release and now raises a clean ``TypeError``.
    """
    if not isinstance(key, OpKey):
        raise TypeError(
            "select() takes an OpKey(op, m, n, k, dsize, g); the legacy "
            "positional (m, n, k[, dsize]) form was removed — build an "
            "OpKey('NT', m, n, k, dsize) instead"
        )
    op = check_op(key.op)
    g = int(key.g)
    if g < 1:
        raise ValueError(f"OpKey batch extent g={g} must be >= 1")
    if g != 1 and op not in GROUPED_OPS:
        # an unbatched op measured/labelled under g>1 would poison the
        # cache and the selector's training rows with an extent the GEMM
        # never ran at
        raise ValueError(
            f"OpKey op {op!r} is unbatched; batch extent g={g} is only "
            f"meaningful for {GROUPED_OPS}"
        )
    return OpKey(op, int(key.m), int(key.n), int(key.k), int(key.dsize), g)


def shape_key(mnk: Sequence[int]) -> str:
    """Stable string form of an (m, n, k) shape — the per-shape tile-table
    key in v3+ selector artifacts (same ``x``-joined style as tile-config
    keys).  Batched ops key their per-slice shape: the tile space tiles
    one slice, so ``g`` does not enter."""
    m, n, k = mnk
    return f"{int(m)}x{int(n)}x{int(k)}"


def parse_shape_key(key: str) -> Tuple[int, int, int]:
    """Inverse of ``shape_key``; raises ``ValueError`` on malformed keys."""
    try:
        parts = tuple(int(p) for p in key.split("x"))
    except ValueError:
        raise ValueError(f"malformed shape key {key!r}") from None
    if len(parts) != 3 or any(p <= 0 for p in parts):
        raise ValueError(f"malformed shape key {key!r}")
    return parts
