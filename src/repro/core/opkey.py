"""The op-space selection key: *(op kind x shape x dtype width)*.

The paper's 28% end-to-end speedup comes from routing the *training*
GEMMs — the forward NT plus the backward data/weight gradients — through
learned selection.  Those three matmuls of a dense layer are distinct
*operations*, not just distinct shapes:

  NT   C = A @ B^T    A:(m, k)  B:(n, k)   forward of a (out, in) dense
  NN   C = A @ B      A:(m, k)  B:(k, n)   data gradient  dX = dY @ W
  TN   C = A^T @ B    A:(k, m)  B:(k, n)   weight gradient dW = dY^T @ X

``OpKey`` names one dispatch decision point: which op, at which logical
(m, n, k) — m/n are the output extents, k the contraction — and at which
element size.  Every ``SelectionPolicy.select`` takes an ``OpKey`` and the
whole persistence stack (measurement caches, selector artifacts, dispatch
reports) is keyed by it, so the selection space is genuinely
*(op x shape x tile config)* — the same generalization AutoTVM made from
per-kernel to per-operator learned cost models.

Legacy positional ``select(m, n, k, dsize)`` calls are adapted by
``coerce_key`` (they mean ``op="NT"``, the only op the old API could
express); that shim is deprecated and kept for one release.
"""

from __future__ import annotations

from typing import NamedTuple, Optional, Sequence, Tuple

__all__ = ["OPS", "OpKey", "check_op", "coerce_key", "shape_key", "parse_shape_key"]

# The op kinds of the dense layer's training GEMMs.  Closed under
# differentiation: d(NT) -> {NN, TN}, d(NN) -> {NT, TN}, d(TN) -> {NT, NN},
# which is what lets the dispatch engine's custom_vjp re-enter itself.
OPS: Tuple[str, ...] = ("NT", "NN", "TN")


def check_op(op: str) -> str:
    if op not in OPS:
        raise ValueError(f"unknown op kind {op!r}; expected one of {OPS}")
    return op


class OpKey(NamedTuple):
    """One dispatch decision point: op kind, logical output/contraction
    extents, and element size.  ``m``/``n`` are the *output* dims and ``k``
    the contraction dim regardless of op, so (m, n, k) reads the same way
    for all three ops (the storage layouts differ, see module docstring)."""

    op: str
    m: int
    n: int
    k: int
    dsize: int = 4

    def mnk(self) -> Tuple[int, int, int]:
        return (self.m, self.n, self.k)


def coerce_key(
    key,
    n: Optional[int] = None,
    k: Optional[int] = None,
    dsize: int = 4,
) -> OpKey:
    """Normalise a ``select`` argument list to an ``OpKey``.

    Accepts an ``OpKey`` (the op-space API) or the legacy positional form
    ``select(m, n, k[, dsize])`` — which could only ever mean the forward
    NT op, so that is what it maps to.  The positional form is deprecated;
    it is kept so pre-redesign policies and call sites keep working for one
    release.
    """
    if isinstance(key, OpKey):
        return OpKey(
            check_op(key.op), int(key.m), int(key.n), int(key.k), int(key.dsize)
        )
    if n is None or k is None:
        raise TypeError(
            "select() takes an OpKey or the legacy positional (m, n, k[, dsize])"
        )
    return OpKey("NT", int(key), int(n), int(k), int(dsize))


def shape_key(mnk: Sequence[int]) -> str:
    """Stable string form of an (m, n, k) shape — the per-shape tile-table
    key in v3 selector artifacts (same ``x``-joined style as tile-config
    keys)."""
    m, n, k = mnk
    return f"{int(m)}x{int(n)}x{int(k)}"


def parse_shape_key(key: str) -> Tuple[int, int, int]:
    """Inverse of ``shape_key``; raises ``ValueError`` on malformed keys."""
    try:
        parts = tuple(int(p) for p in key.split("x"))
    except ValueError:
        raise ValueError(f"malformed shape key {key!r}") from None
    if len(parts) != 3 or any(p <= 0 for p in parts):
        raise ValueError(f"malformed shape key {key!r}")
    return parts
