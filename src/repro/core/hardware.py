"""Hardware descriptors — the paper's Table III, adapted to TPU.

The paper's 5 GPU features ``(gm, sm, cc, mbw, l2c)`` map to:

  gm  -> mem_gib       device memory (HBM / host RAM), GiB
  sm  -> num_cores     parallel compute units (TensorCores / host cores)
  cc  -> clock_mhz     core clock
  mbw -> mem_bw_gbps   memory bandwidth, GB/s  (paper used bus width; the
                       bandwidth is the architecture-portable equivalent)
  l2c -> sram_kib      on-chip staging SRAM (VMEM for TPU, L2 for CPU), KiB

``peak_tflops``/``ici_gbps`` are *not* features (the paper uses exactly 5
hardware dims); they feed the analytic cost model and the roofline.
"""

from __future__ import annotations

import os
from dataclasses import dataclass
from typing import Dict, Tuple

__all__ = [
    "HardwareSpec",
    "TPU_V5E",
    "TPU_V4",
    "TPU_V5P",
    "SIMULATED_CHIPS",
    "host_spec",
]


@dataclass(frozen=True)
class HardwareSpec:
    name: str
    mem_gib: float
    num_cores: int
    clock_mhz: float
    mem_bw_gbps: float
    sram_kib: float
    # cost-model-only attributes (not classifier features):
    peak_tflops_bf16: float
    peak_tflops_f32: float
    ici_gbps: float = 50.0
    launch_overhead_us: float = 2.0
    transpose_bw_frac: float = 0.80  # paper [20]: out-of-place hits ~80% peak

    def features(self) -> Tuple[float, float, float, float, float]:
        """The paper's 5 hardware feature dims."""
        return (
            self.mem_gib,
            float(self.num_cores),
            self.clock_mhz,
            self.mem_bw_gbps,
            self.sram_kib,
        )


# -- target TPU chips (the analytic-dataset "GPUs") -------------------------
TPU_V5E = HardwareSpec(
    name="tpu_v5e",
    mem_gib=16.0,
    num_cores=1,
    clock_mhz=940.0,
    mem_bw_gbps=819.0,
    sram_kib=128 * 1024,
    peak_tflops_bf16=197.0,
    peak_tflops_f32=98.5,
    ici_gbps=50.0,
)
TPU_V4 = HardwareSpec(
    name="tpu_v4",
    mem_gib=32.0,
    num_cores=2,
    clock_mhz=1050.0,
    mem_bw_gbps=1228.0,
    sram_kib=128 * 1024,
    peak_tflops_bf16=275.0,
    peak_tflops_f32=137.5,
    ici_gbps=100.0,
)
TPU_V5P = HardwareSpec(
    name="tpu_v5p",
    mem_gib=95.0,
    num_cores=2,
    clock_mhz=1750.0,
    mem_bw_gbps=2765.0,
    sram_kib=128 * 1024,
    peak_tflops_bf16=459.0,
    peak_tflops_f32=229.5,
    ici_gbps=100.0,
)

SIMULATED_CHIPS: Dict[str, HardwareSpec] = {
    c.name: c for c in (TPU_V5E, TPU_V4, TPU_V5P)
}


def host_spec() -> HardwareSpec:
    """Best-effort descriptor of the *current* host (for measured-CPU data)."""
    ncpu = os.cpu_count() or 1
    mem_gib = 16.0
    try:
        with open("/proc/meminfo") as fh:
            for line in fh:
                if line.startswith("MemTotal"):
                    mem_gib = float(line.split()[1]) / (1024**2)
                    break
    except OSError:
        pass
    clock = 2000.0
    try:
        with open("/proc/cpuinfo") as fh:
            for line in fh:
                if "cpu MHz" in line:
                    clock = float(line.split(":")[1])
                    break
    except OSError:
        pass
    return HardwareSpec(
        name="host_cpu",
        mem_gib=round(mem_gib, 1),
        num_cores=ncpu,
        clock_mhz=clock,
        mem_bw_gbps=50.0,
        sram_kib=1024.0,
        peak_tflops_bf16=ncpu * 0.05,
        peak_tflops_f32=ncpu * 0.05,
        ici_gbps=10.0,
    )
