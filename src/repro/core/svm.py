"""Minimal kernel SVM trained with simplified SMO (Platt 1998).

Used only for the paper's Table VI comparison (GBDT vs SVM-RBF vs SVM-Poly
vs DT).  libSVM is not available offline; this is a compact, deterministic
re-implementation sufficient for the ~2k-sample selection dataset.

Paper hyper-parameters: C = 1000.0, gamma = 0.01, features normalised to
(0, 1) before training (normalisation lives in the caller, see
``core.train_model``).
"""

from __future__ import annotations

from typing import Callable, Optional

import numpy as np

__all__ = ["SVMClassifier", "rbf_kernel", "poly_kernel"]


def rbf_kernel(gamma: float) -> Callable[[np.ndarray, np.ndarray], np.ndarray]:
    def k(a: np.ndarray, b: np.ndarray) -> np.ndarray:
        aa = (a * a).sum(axis=1)[:, None]
        bb = (b * b).sum(axis=1)[None, :]
        d2 = np.maximum(aa + bb - 2.0 * a @ b.T, 0.0)
        return np.exp(-gamma * d2)

    return k


def poly_kernel(gamma: float, degree: int = 3, coef0: float = 0.0):
    def k(a: np.ndarray, b: np.ndarray) -> np.ndarray:
        return (gamma * (a @ b.T) + coef0) ** degree

    return k


class SVMClassifier:
    """Binary SVM, labels in {-1, +1}."""

    def __init__(
        self,
        C: float = 1000.0,
        kernel: str = "rbf",
        gamma: float = 0.01,
        degree: int = 3,
        tol: float = 1e-3,
        max_passes: int = 5,
        max_iter: int = 2000,
        seed: int = 0,
    ):
        self.C = C
        self.kernel_name = kernel
        self.gamma = gamma
        self.degree = degree
        self.tol = tol
        self.max_passes = max_passes
        self.max_iter = max_iter
        self.seed = seed
        self._kfn = (
            rbf_kernel(gamma) if kernel == "rbf" else poly_kernel(gamma, degree)
        )
        self.alpha: Optional[np.ndarray] = None
        self.b = 0.0
        self.X: Optional[np.ndarray] = None
        self.y: Optional[np.ndarray] = None

    def fit(self, X: np.ndarray, y: np.ndarray) -> "SVMClassifier":
        X = np.asarray(X, dtype=np.float64)
        y = np.where(np.asarray(y) > 0, 1.0, -1.0)
        n = len(y)
        K = self._kfn(X, X)
        alpha = np.zeros(n)
        b = 0.0
        rng = np.random.RandomState(self.seed)

        def f(i):
            return (alpha * y) @ K[:, i] + b

        passes = 0
        it = 0
        while passes < self.max_passes and it < self.max_iter:
            it += 1
            changed = 0
            for i in range(n):
                Ei = f(i) - y[i]
                if (y[i] * Ei < -self.tol and alpha[i] < self.C) or (
                    y[i] * Ei > self.tol and alpha[i] > 0
                ):
                    j = rng.randint(n - 1)
                    if j >= i:
                        j += 1
                    Ej = f(j) - y[j]
                    ai, aj = alpha[i], alpha[j]
                    if y[i] != y[j]:
                        L, H = max(0.0, aj - ai), min(self.C, self.C + aj - ai)
                    else:
                        L, H = max(0.0, ai + aj - self.C), min(self.C, ai + aj)
                    if L >= H:
                        continue
                    eta = 2.0 * K[i, j] - K[i, i] - K[j, j]
                    if eta >= 0:
                        continue
                    alpha[j] = np.clip(aj - y[j] * (Ei - Ej) / eta, L, H)
                    if abs(alpha[j] - aj) < 1e-7:
                        continue
                    alpha[i] = ai + y[i] * y[j] * (aj - alpha[j])
                    b1 = (
                        b
                        - Ei
                        - y[i] * (alpha[i] - ai) * K[i, i]
                        - y[j] * (alpha[j] - aj) * K[i, j]
                    )
                    b2 = (
                        b
                        - Ej
                        - y[i] * (alpha[i] - ai) * K[i, j]
                        - y[j] * (alpha[j] - aj) * K[j, j]
                    )
                    if 0 < alpha[i] < self.C:
                        b = b1
                    elif 0 < alpha[j] < self.C:
                        b = b2
                    else:
                        b = 0.5 * (b1 + b2)
                    changed += 1
            passes = passes + 1 if changed == 0 else 0

        sv = alpha > 1e-8
        self.alpha = alpha[sv]
        self.X = X[sv]
        self.y = y[sv]
        self.b = b
        return self

    def decision_function(self, X: np.ndarray) -> np.ndarray:
        X = np.asarray(X, dtype=np.float64)
        if self.X is None or len(self.X) == 0:
            return np.zeros(len(X))
        return (self.alpha * self.y) @ self._kfn(self.X, X) + self.b

    def predict(self, X: np.ndarray) -> np.ndarray:
        return np.where(self.decision_function(X) >= 0, 1, -1)
